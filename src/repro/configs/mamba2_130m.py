"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128; SSD (state-space duality).  [arXiv:2405.21060]

Pure SSM: mixer-only blocks (d_ff=0 per the assignment; ffn slot "none").
§Arch-applicability: LANS applies unchanged — the optimizer's blocks are
parameter tensors (A_log, conv, projections), not attention structures.
"""
from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

CONFIG = DecoderConfig(
    name="mamba2-130m",
    n_layers=24,
    d_model=768,
    n_heads=12,          # unused (attention-free); kept for config plumbing
    n_kv_heads=12,
    d_ff=0,
    vocab=50280,
    mamba_d_inner=1536,
    mamba_headdim=64,
    mamba_dstate=128,
    mamba_chunk=64,
    tie_embeddings=True,
    superblock=(("mamba", "none"),),
    max_seq=1048576,
)

ARCH = Arch(
    name="mamba2-130m",
    kind="decoder",
    cfg=CONFIG,
    source="arXiv:2405.21060",
    long_context_ok=True,   # O(1) state per token
)

"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2; Mamba:attention 7:1
interleave with MoE every other layer.  [arXiv:2403.19887]

Superblock of 8 (72 = 9 periods): attention at slot 0, Mamba at slots 1-7;
MoE FFN on odd slots, dense FFN on even — the 1:7 ratio and every-other-
layer MoE of the Jamba paper. Hardware adaptation note (DESIGN.md): Jamba
uses Mamba-1 selective-scan blocks; this framework implements the Mamba-2
SSD chunked form, which is the TPU/MXU-native formulation of the same
selective-state-space computation.
"""
import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

_PERIOD = (
    ("attn", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
)

CONFIG = DecoderConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    activation="silu",
    mamba_d_inner=16384,
    mamba_headdim=128,
    mamba_dstate=128,
    mamba_chunk=64,
    superblock=_PERIOD,
    max_seq=262144,
    param_dtype=jnp.bfloat16,  # 398B: no fp32 master on 16GB chips (DESIGN.md)
)

ARCH = Arch(
    name="jamba-1.5-large-398b",
    kind="decoder",
    cfg=CONFIG,
    source="arXiv:2403.19887",
    zero3=True,
    train_microbatches=8,  # traffic-vs-activation-memory balance (EXPERIMENTS.md iter 3)
    long_context_ok=True,   # mamba slots O(1)/token; 1-in-8 attn linear/token
)

"""whisper-large-v3 [audio] — enc-dec, 32L (per stack) d_model=1280 20H
(MHA: kv=20) d_ff=5120 vocab=51866; conv/mel frontend stubbed.
[arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is the allowed STUB:
input_specs() yields precomputed frame embeddings (B, 1500, 1280). The
decode shapes stress the decoder backbone with KV caches far past the
model card's 448-token form factor (documented in DESIGN.md).
"""
from repro.configs.base import Arch
from repro.models.encdec import EncDecConfig

CONFIG = EncDecConfig(
    name="whisper-large-v3",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    d_ff=5120,
    vocab=51866,
    n_frames=1500,
    max_target=32768,   # stress config for the assigned decode shapes
)

ARCH = Arch(
    name="whisper-large-v3",
    kind="encdec",
    cfg=CONFIG,
    source="arXiv:2212.04356",
    notes="encoder bidirectional over 1500 stub frame embeddings; "
          "decode shapes exercise the decoder backbone only.",
)

"""Arch abstraction: binds a model family to the assigned input shapes.

Every assigned architecture file exports ``ARCH = Arch(...)`` built from the
exact public config. ``Arch`` dispatches init / loss / prefill / decode on
the model kind and provides ShapeDtypeStruct ``input_specs`` for the
dry-run (no allocation, weak-type-correct).

The four assigned input shapes:
  train_4k     seq 4096    global_batch 256   -> train_step
  prefill_32k  seq 32768   global_batch 32    -> prefill_step
  decode_32k   seq 32768   global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288  global_batch 1     -> serve_step; sub-quadratic
                                                 archs only (see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import bert as bert_lib
from repro.models import decoder as dec_lib
from repro.models import encdec as ed_lib


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    kind: str                      # "decoder" | "encdec" | "bert"
    cfg: Any
    source: str                    # citation for the config
    zero3: bool = False            # FSDP over the data axis (>=100B params)
    zero1: bool = False            # ZeRO-1: shard only optimizer moments
    long_context_ok: bool = False  # sub-quadratic / windowed: run long_500k
    embeds_input: bool = False     # VLM/audio stub: model consumes embeddings
    train_microbatches: int = 4    # grad-accum splits of the global batch
    notes: str = ""

    # ---------------- model dispatch ----------------

    def init(self, rng):
        if self.kind == "decoder":
            return dec_lib.decoder_init(rng, self.cfg)
        if self.kind == "encdec":
            return ed_lib.encdec_init(rng, self.cfg)
        if self.kind == "bert":
            return bert_lib.bert_init(rng, self.cfg)
        raise ValueError(self.kind)

    def loss_fn(self, params, batch):
        """(loss, aux) for one batch — what train_step differentiates."""
        if self.kind == "decoder":
            big_vocab = self.cfg.vocab >= 65536
            seq = batch["labels"].shape[1]
            chunked = big_vocab and seq >= 1024 and seq % 512 == 0
            kw = (dict(embeds=batch["embeds"])
                  if self.embeds_input and "embeds" in batch
                  else dict(tokens=batch["tokens"]))
            if chunked:
                hidden, _, aux = dec_lib.decoder_apply(
                    params, self.cfg, return_hidden=True, **kw)
                loss = dec_lib.chunked_lm_loss(
                    params, self.cfg, hidden, batch["labels"],
                    moe_aux=aux.get("moe_aux_loss"))
            else:
                logits, _, aux = dec_lib.decoder_apply(params, self.cfg, **kw)
                loss = dec_lib.lm_loss(logits, batch["labels"],
                                       moe_aux=aux.get("moe_aux_loss"))
            return loss, {"router_entropy": aux.get("router_entropy", 0.0)}
        if self.kind == "encdec":
            logits = ed_lib.encdec_apply(params, self.cfg,
                                         batch["frames"], batch["tokens"])
            loss = dec_lib.lm_loss(logits, batch["labels"])
            return loss, {}
        if self.kind == "bert":
            return bert_lib.bert_pretrain_loss(params, self.cfg, batch)
        raise ValueError(self.kind)

    # ---------------- serving ----------------

    def init_cache(self, batch: int, max_len: int, *, per_slot: bool = False,
                   clamp_window: bool = True):
        if self.kind == "decoder":
            return dec_lib.init_decoder_cache(self.cfg, batch, max_len,
                                              per_slot=per_slot,
                                              clamp_window=clamp_window)
        if self.kind == "encdec":
            return ed_lib.init_encdec_cache(self.cfg, batch, max_len,
                                            dtype=self.cfg.compute_dtype,
                                            per_slot=per_slot)
        raise ValueError(f"{self.kind} has no decode cache")

    def init_paged_cache(self, batch: int, max_len: int, *,
                         block_size: int = 16, n_blocks=None,
                         row_margin: int = 0):
        """Paged (block-arena) serving cache — decoder-only.

        n_blocks defaults to the dense-equivalent budget: `batch` slots'
        worth of blocks per attention slot-type (ring length // block
        size each), so a no-sharing workload fits exactly as many slots
        as the dense pool while shared prompt prefixes fit more.
        row_margin widens sliding-window rings for speculative K-row
        verify bursts — see models/decoder.paged_layout.
        """
        if self.kind != "decoder":
            raise NotImplementedError("paged serving is decoder-only")
        if n_blocks is None:
            layout = dec_lib.paged_layout(self.cfg, max_len, block_size,
                                          row_margin)
            n_blocks = {si: batch * (ring // block_size)
                        for si, ring in filter(None, layout)}
        return dec_lib.init_paged_decoder_cache(
            self.cfg, batch, max_len, block_size=block_size,
            n_blocks=n_blocks, row_margin=row_margin)

    def paged_cache_specs(self, shape_name: str, *, block_size: int = 16):
        """Abstract paged cache for the dry-run decode shapes — the HLO
        the production mesh actually serves (block-table gather included).

        Arenas are sized one null block short of the dense-equivalent
        budget so the total blocks dim stays divisible by the data axis —
        that is the dim the pool shards across chips."""
        shape = SHAPES[shape_name]
        layout = dec_lib.paged_layout(self.cfg, shape.seq_len, block_size)
        n_blocks = {si: shape.global_batch * (ring // block_size) - 1
                    for si, ring in filter(None, layout)}
        return jax.eval_shape(
            lambda: self.init_paged_cache(shape.global_batch, shape.seq_len,
                                          block_size=block_size,
                                          n_blocks=n_blocks))

    def prefill(self, params, batch, *, cache_len: Optional[int] = None,
                per_slot: bool = False, positions=None):
        """Full-sequence forward with cache writes -> (last_logits, cache).

        cache_len > prompt length leaves room for subsequent decode steps.
        per_slot=True uses the pooled cache layout (per-batch cursors);
        positions (B, S) overrides the default 0..S-1 timeline — left-padded
        batches pass local positions with pads < 0 so padding is masked out
        of attention/SSM/MoE state (left-pad invariant prefill).
        """
        if self.kind == "decoder":
            toks = batch["tokens"]
            cache = dec_lib.init_decoder_cache(
                self.cfg, toks.shape[0], cache_len or toks.shape[1],
                per_slot=per_slot)
            logits, cache, _ = dec_lib.decoder_apply(
                params, self.cfg, toks, caches=cache, positions=positions)
            return logits[:, -1:], cache
        if self.kind == "encdec":
            toks = batch["tokens"]
            if per_slot:
                # Pooled serving admission: encode + one-time cross K/V
                # projection + prompt prefill into per-slot caches.
                return ed_lib.prefill_serve(
                    params, self.cfg, toks, positions, batch["frames"],
                    cache_len or toks.shape[1])
            memory = ed_lib.encode(params, self.cfg, batch["frames"])
            cache = ed_lib.init_encdec_cache(
                self.cfg, toks.shape[0], cache_len or toks.shape[1])
            logits, cache = ed_lib.decode(params, self.cfg, toks, memory,
                                          caches=cache)
            return logits[:, -1:], cache
        raise ValueError(f"{self.kind} does not serve")

    def decode_step(self, params, batch, cache):
        """One new token against the cache -> (logits, new_cache).

        batch may carry "positions" (B, S) — per-slot local timelines for
        the pooled serving cache (defaults to the cache write cursor).
        """
        if self.kind == "decoder":
            logits, cache, _ = dec_lib.decoder_apply(
                params, self.cfg, batch["tokens"], caches=cache,
                positions=batch.get("positions"))
            return logits, cache
        if self.kind == "encdec":
            if "slots" in cache:
                # Pooled serving layout: cross K/V ride inside the cache
                # (dense or paged arena) — no per-step memory operand.
                return ed_lib.decode_serve(params, self.cfg,
                                           batch["tokens"],
                                           batch["positions"], cache)
            return ed_lib.decode(params, self.cfg, batch["tokens"],
                                 batch["memory"], caches=cache)
        raise ValueError(f"{self.kind} does not serve")

    def score(self, params, tokens, positions):
        """Batched scoring forward (BERT family) -> (mlm_ids, pooled).

        tokens/positions (B, S) left-padded (pads < 0): masked-LM argmax
        ids per position plus the fp32 tanh-pooled [CLS] embedding — the
        serving engine's score/embed step (no KV cache, no growth).
        """
        if self.kind != "bert":
            raise ValueError(f"{self.kind} has no scoring forward")
        return bert_lib.bert_serve_outputs(params, self.cfg, tokens,
                                           positions)

    # ---------------- dry-run input specs ----------------

    def supports(self, shape_name: str) -> bool:
        shape = SHAPES[shape_name]
        if self.kind == "bert" and shape.kind != "train":
            return False
        if shape.name == "long_500k":
            return self.long_context_ok
        return True

    def input_specs(self, shape_name: str) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        shape = SHAPES[shape_name]
        B, S = shape.global_batch, shape.seq_len
        i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        f32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)

        if self.kind == "bert":
            return {"tokens": i32((B, S)), "token_types": i32((B, S)),
                    "mlm_labels": i32((B, S)), "nsp_labels": i32((B,))}

        if self.kind == "encdec":
            frames = f32((B, self.cfg.n_frames, self.cfg.d_model))
            if shape.kind == "train":
                return {"frames": frames, "tokens": i32((B, S)),
                        "labels": i32((B, S))}
            if shape.kind == "prefill":
                return {"frames": frames, "tokens": i32((B, S))}
            return {"tokens": i32((B, 1)),
                    "memory": f32((B, self.cfg.n_frames, self.cfg.d_model))}

        # decoder family
        if shape.kind == "train":
            batch = {"tokens": i32((B, S)), "labels": i32((B, S))}
            if self.embeds_input:
                batch = {"embeds": f32((B, S, self.cfg.d_model)),
                         "labels": i32((B, S))}
            return batch
        if shape.kind == "prefill":
            return {"tokens": i32((B, S))}
        return {"tokens": i32((B, 1))}

    def cache_specs(self, shape_name: str):
        shape = SHAPES[shape_name]
        return jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_count(self) -> int:
        import math
        return sum(math.prod(l.shape) for l in
                   jax.tree.leaves(self.abstract_params()))


def reduced_decoder(cfg: dec_lib.DecoderConfig, **over) -> dec_lib.DecoderConfig:
    """Smoke-test variant: one superblock period x2, d_model<=256, <=4 experts."""
    n_slots = len(cfg.superblock)
    small = dict(
        n_layers=max(2, n_slots) if n_slots > 1 else 2,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=64,
        d_ff=512 if cfg.n_experts == 0 else 256,
        vocab=1024,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        mamba_d_inner=512 if cfg.mamba_d_inner else None,
        mamba_headdim=64,
        mamba_dstate=32,
        mamba_chunk=16,
        sliding_window=16 if cfg.sliding_window else None,
        max_seq=256,
        param_dtype=jnp.float32,  # smoke numerics even for bf16 prod configs
    )
    small.update(over)
    # superblock must still divide n_layers
    if small["n_layers"] % n_slots != 0:
        small["n_layers"] = n_slots * max(1, small["n_layers"] // n_slots)
    return dataclasses.replace(cfg, **small)

"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1]"""
import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

CONFIG = DecoderConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    activation="gelu",
    gated_mlp=True,
    superblock=(("attn", "moe"),),
    max_seq=8192,
    param_dtype=jnp.bfloat16,  # 314B: no fp32 master on 16GB chips (DESIGN.md)
)

ARCH = Arch(
    name="grok-1-314b",
    kind="decoder",
    cfg=CONFIG,
    source="hf:xai-org/grok-1",
    zero3=True,
    train_microbatches=8,  # traffic-vs-activation-memory balance (EXPERIMENTS.md iter 3)           # 314B params: FSDP over the data axis required
    long_context_ok=False,  # full attention, no windowed variant
    notes="MoE 8e top-2; experts < model axis (8 < 16) so the ff dim is "
          "expert-sharded instead (see distributed/sharding.py).",
)

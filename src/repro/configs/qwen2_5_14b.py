"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B]"""
from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

CONFIG = DecoderConfig(
    name="qwen2.5-14b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    activation="silu",
    superblock=(("attn", "mlp"),),
    max_seq=32768,
)

ARCH = Arch(
    name="qwen2.5-14b",
    kind="decoder",
    cfg=CONFIG,
    source="hf:Qwen/Qwen2.5-0.5B",
)

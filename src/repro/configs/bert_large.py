"""bert-large — the paper's own pretraining workload (Devlin et al. 2019).

24L / 1024d / 16H / ff 4096 / vocab 30522. Trained with LANS at batch
96K (phase 1, seq 128) and 33K (phase 2, seq 512) in the paper.
Not part of the 10 assigned archs; included because the paper's Table 2
experiment is reproduced on it (benchmarks/table2_convergence.py,
examples/bert_pretraining.py).
"""
from repro.configs.base import Arch
from repro.models.bert import BertConfig

CONFIG = BertConfig(
    name="bert-large",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    d_ff=4096,
    vocab=30522,
    max_pos=512,
)

ARCH = Arch(
    name="bert-large",
    kind="bert",
    cfg=CONFIG,
    source="arXiv:1810.04805 / LANS paper §4",
)

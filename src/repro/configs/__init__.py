"""Architecture registry: the 10 assigned archs + the paper's bert-large."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import SHAPES, Arch, InputShape, reduced_decoder

_MODULES = {
    "grok-1-314b": "grok_1_314b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2.5-32b": "qwen2_5_32b",
    "chameleon-34b": "chameleon_34b",
    "whisper-large-v3": "whisper_large_v3",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-130m": "mamba2_130m",
    "gemma2-2b": "gemma2_2b",
    "bert-large": "bert_large",
}

ASSIGNED = [k for k in _MODULES if k != "bert-large"]


def get_arch(name: str) -> Arch:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.ARCH


def all_archs() -> Dict[str, Arch]:
    return {name: get_arch(name) for name in _MODULES}


def reduced_arch(name: str) -> Arch:
    """Smoke-test variant: <=2 periods, d_model<=256, <=4 experts, vocab 1k."""
    arch = get_arch(name)
    if arch.kind == "decoder":
        return dataclasses.replace(arch, cfg=reduced_decoder(arch.cfg),
                                   zero3=False)
    if arch.kind == "encdec":
        small = dataclasses.replace(
            arch.cfg, n_layers=2, d_model=128, n_heads=4, d_ff=256,
            vocab=512, n_frames=16, max_target=64)
        return dataclasses.replace(arch, cfg=small, zero3=False)
    if arch.kind == "bert":
        small = dataclasses.replace(
            arch.cfg, n_layers=2, d_model=128, n_heads=4, d_ff=256,
            vocab=512, max_pos=128)
        return dataclasses.replace(arch, cfg=small, zero3=False)
    raise ValueError(arch.kind)


__all__ = ["SHAPES", "Arch", "InputShape", "ASSIGNED", "get_arch",
           "all_archs", "reduced_arch"]

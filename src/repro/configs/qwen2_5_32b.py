"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064; GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B]"""
import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

CONFIG = DecoderConfig(
    name="qwen2.5-32b",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    activation="silu",
    superblock=(("attn", "mlp"),),
    max_seq=32768,
    param_dtype=jnp.bfloat16,  # no fp32 master at 32B on 16GB chips
)

ARCH = Arch(
    name="qwen2.5-32b",
    kind="decoder",
    cfg=CONFIG,
    source="hf:Qwen/Qwen2.5-0.5B",
    zero1=True,  # ZeRO-1 (moments sharded) beats zero3 here: EXPERIMENTS.md iter 2
    train_microbatches=16,
)

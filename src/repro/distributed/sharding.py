"""Partition-spec rules for the production mesh.

Mesh axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
"pod" is an outer pure-data-parallel axis (the paper's scale-out pattern);
batch dims shard over ("pod", "data") jointly.

Parameter rules (path-keyword driven, rank-aware, with stacked-layer leading
dims skipped automatically):

  embeddings   (V, d)          -> vocab over model
  wq/wk/wv     (d, heads*hd)   -> columns over model
  wo           (heads*hd, d)   -> rows over model
  mlp up/gate  (d, f)          -> columns over model;  down: rows over model
  moe experts  (E, din, dout)  -> experts over model (fallback: ff dim when
                                  E % model_size != 0 — granite's 40, grok's 8)
  mamba in/out projections     -> inner dim over model
  1-D params (biases, norms, A_log, ...) -> replicated

FSDP (zero3=True): additionally shard the largest remaining eligible dim
over "data" — required for grok-1 (314B) and jamba (398B) to fit 16 GB HBM.

KV caches: (layers, B, L, kv, hd) -> batch over data, head_dim over model
(contracting-dim sharding; SPMD inserts the psum). SSM states: dstate over
model.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.optim.base import tree_paths

PyTree = Any


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _divisible(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def param_spec(path: str, shape: tuple, mesh: Mesh, *, zero3: bool = False,
               n_stack_dims: int = 0) -> P:
    """PartitionSpec for one parameter tensor.

    n_stack_dims: leading stacked-layer dims (scan over periods) left unsharded.
    """
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    low = path.lower()
    core_shape = shape[n_stack_dims:]
    rank = len(core_shape)
    spec = [None] * rank

    def put(axis_idx: int, name: str) -> bool:
        if spec[axis_idx] is None and _divisible(core_shape[axis_idx],
                                                 _axis_size(mesh, name)):
            spec[axis_idx] = name
            return True
        return False

    if rank >= 2:
        is_expert_stack = rank == 3 and ("up" in low or "down" in low or
                                         "gate" in low)
        if is_expert_stack:
            # (E, din, dout): experts over model, else the ff dim.
            if not put(0, "model"):
                ff_axis = 2 if "up" in low or "gate" in low else 1
                put(ff_axis, "model")
        elif "embed" in low or "lm_head" in low or "mlm" in low:
            # (V, d) embedding tables / (d, V) heads: shard the vocab dim.
            v_axis = int(np.argmax(core_shape))
            put(v_axis, "model")
        elif any(k in low for k in ("wq", "wk", "wv", "up", "gate", "in_proj",
                                    "router", "pooler", "nsp", "transform")):
            put(rank - 1, "model")            # column parallel
        elif any(k in low for k in ("wo", "down", "out_proj")):
            put(rank - 2, "model")            # row parallel
        else:
            put(int(np.argmax(core_shape)), "model")

        if zero3:
            # FSDP/ZeRO: shard the largest remaining dim over the FULL
            # data-parallel extent (pod x data when a pod axis exists) —
            # data-only sharding replicated optimizer state across pods and
            # regressed qwen32 pod2 collectives 11x (EXPERIMENTS iter 5).
            psize = _axis_size(mesh, "pod")
            candidates = ([("pod", "data"), "data"] if psize > 1
                          else ["data"])
            order = list(np.argsort(core_shape))[::-1]
            done = False
            for axes in candidates:
                if done:
                    break
                size = (psize * dsize if isinstance(axes, tuple) else dsize)
                for ax in order:
                    if spec[ax] is None and _divisible(core_shape[ax], size):
                        spec[ax] = axes
                        done = True
                        break
    # rank 0/1: replicated.
    return P(*([None] * n_stack_dims + spec))


def _stack_dims_for(path: str) -> int:
    low = path.lower()
    if low.startswith(("slot", "enc_layers", "dec_layers", "layers")):
        return 1
    return 0


def params_pspec(params: PyTree, mesh: Mesh, *, zero3: bool = False) -> PyTree:
    paths = tree_paths(params)
    return jax.tree.map(
        lambda pth, v: param_spec(pth, tuple(v.shape), mesh, zero3=zero3,
                                  n_stack_dims=_stack_dims_for(pth)),
        paths, params)


def params_sharding(params: PyTree, mesh: Mesh, *, zero3: bool = False) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspec(params, mesh, zero3=zero3))


def _master_pspec(params_spec: PyTree, master_like: PyTree) -> PyTree:
    """Master weights mirror their parameter's spec; the zero-size
    placeholders the mixed-precision wrapper stores for fp32-kept leaves
    (LN/bias) replicate."""
    def spec(s, m):
        size = int(np.prod(getattr(m, "shape", ())))
        return s if size else P()

    return jax.tree.map(spec, params_spec, master_like,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_pspec(opt_state: PyTree, params_spec: PyTree,
                    moments_spec: PyTree = None) -> PyTree:
    """Optimizer moments inherit their parameter's spec; counters replicate.

    moments_spec overrides the moment sharding — pass a zero3-style spec
    for ZeRO-1 (optimizer-state sharding over "data" while weights stay
    only model-sharded; see EXPERIMENTS.md §Perf iteration 2).

    Works for the (LansState | LambState | AdamWState | FusedState, sched)
    chain states used across this repo (any leaf whose subtree path starts
    with mu/nu mirrors params) and for the mixed-precision states
    (MixedPrecisionState wrapping a chain state; FusedMixedState), whose
    fp32 master weights mirror params and loss-scale scalars replicate.
    """
    mspec = moments_spec if moments_spec is not None else params_spec
    from repro.precision.fused import FusedMixedState
    from repro.precision.mixed import MixedPrecisionState

    # Masters are optimizer state: they follow the (ZeRO-1 aware) moments
    # spec, not the weights spec, so optimizer-state sharding over "data"
    # covers the largest fp32 buffer mixed precision adds.
    if isinstance(opt_state, MixedPrecisionState):
        return MixedPrecisionState(
            loss_scale=jax.tree.map(lambda _: P(), opt_state.loss_scale),
            master=_master_pspec(mspec, opt_state.master),
            inner=opt_state_pspec(opt_state.inner, params_spec, moments_spec),
        )
    if isinstance(opt_state, FusedMixedState):
        return FusedMixedState(
            loss_scale=jax.tree.map(lambda _: P(), opt_state.loss_scale),
            count=P(),
            master=_master_pspec(mspec, opt_state.master),
            mu=jax.tree.map(lambda s: s, mspec),
            nu=jax.tree.map(lambda s: s, mspec),
        )
    out = []
    for comp in opt_state:
        if hasattr(comp, "_fields") and set(comp._fields) >= {"mu", "nu"}:
            replaced = comp._replace(
                count=P(),
                mu=jax.tree.map(lambda s: s, mspec),
                nu=jax.tree.map(lambda s: s, mspec))
            out.append(replaced)
        elif hasattr(comp, "_fields") and "momentum" in comp._fields:
            out.append(comp._replace(momentum=jax.tree.map(lambda s: s, mspec)))
        else:
            out.append(jax.tree.map(lambda _: P(), comp))
    return tuple(out)


def batch_pspec(batch: PyTree, mesh: Mesh) -> PyTree:
    """Shard the leading (batch) dim of every input over (pod, data)."""
    baxes = batch_axes(mesh)

    def spec(v):
        if v.ndim == 0:
            return P()
        bsize = int(np.prod([_axis_size(mesh, a) for a in baxes]))
        if v.shape[0] % bsize == 0:
            return P(baxes, *([None] * (v.ndim - 1)))
        return P(*([None] * v.ndim))

    return jax.tree.map(spec, batch)


def cache_pspec(cache: PyTree, mesh: Mesh) -> PyTree:
    """KV / SSM cache sharding for serving — dense and paged layouts.

    kv caches (layers, B, L, kv, hd): B over data (if divisible), hd over
    model (contracting-dim sharding; exact under SPMD).
    ssm states  (layers, B, H, N, P): B over data, N over model.
    conv states (layers, B, W-1, C):  B over data, C over model.
    paged arenas (layers, n_blocks, bsz, kv, hd): BLOCKS over data — the
    pool's capacity dim distributes across chips the way batch rows do in
    the dense pool — hd over model as before.
    encdec cross arenas (layers, n_blocks+1, bsz, H, hd): same shape
    family as paged arenas, so the same rule applies — blocks (axis 1,
    the +1 null block rides along) over data, head_dim over model. The
    cross position rows (n_blocks+1, bsz) and per-slot block table
    (B, max_blocks) fall under the integer rule below. EncDecCachePool
    pins its insert/gather jits to these specs (cache_shardings), so the
    cross arena never re-shards between encoder registration and decode.
    Integer bookkeeping (positions, block tables, cursors) never shards
    over model: only its leading batch/blocks dim goes over data, so the
    block-table gather indexes a locally-addressable table.
    """
    dsize = _axis_size(mesh, "data")
    msize = _axis_size(mesh, "model")
    paths = tree_paths(cache)

    def spec(pth, v):
        if v.ndim <= 1:
            return P(*([None] * v.ndim))
        s = [None] * v.ndim
        # batch/blocks dim is axis 1 for stacked caches (axis 0 = layers)
        b_ax = 1 if v.ndim >= 3 else 0
        if _divisible(v.shape[b_ax], dsize):
            s[b_ax] = "data"
        if not jnp.issubdtype(v.dtype, jnp.integer) and _divisible(
                v.shape[-1], msize):
            s[-1] = "model"
        return P(*s)

    return jax.tree.map(spec, paths, cache)


def constrain(tree: PyTree, mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree, spec_tree)


def cache_shardings(cache: PyTree, mesh: Mesh) -> PyTree:
    """cache_pspec as a NamedSharding tree — what the serving pools pin
    their device caches and jitted mutation ops to, so every host-side
    cache mutation (insert / invalidate / COW copy / rollback) lands its
    output on the SAME layout the sharded decode step consumes. Without
    this the single-device mutation jits would silently replicate their
    outputs and every decode step would re-shard the whole arena."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_pspec(cache, mesh),
                        is_leaf=lambda x: isinstance(x, P))

"""Distributed train / prefill / serve step builders.

Each builder returns a pure function suitable for `jax.jit(...,
in_shardings=..., out_shardings=...)` under the production mesh, plus the
sharding pytrees for its inputs/outputs. The same builders drive the real
training loop (launch/train.py), the serving loop (launch/serve.py) and the
multi-pod dry-run (launch/dryrun.py).

Gradient accumulation: `microbatches > 1` runs a `lax.scan` over microbatch
slices, averaging gradients in fp32 — how the 96K global batch is fed
through a fixed device footprint, matching the paper's setup (96K sequences
over 1536 workers = 62.5/worker, accumulated).

Mixed precision: pass `policy=get_policy("fp16_mixed")` (or "bf16") and the
raw optimizer — the builder wraps it with `mixed_precision` (fp32 master
weights), scales the loss by the loss scale carried in the optimizer state
before `value_and_grad`, accumulates microbatch grads in fp32 as before, and
the wrapper's `lax.cond` skips the step + halves the scale on non-finite
grads. Metrics gain `loss_scale` / `overflow_count` / `grads_finite`.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.optim.base import apply_updates
from repro.distributed import sharding as shd

PyTree = Any


def build_train_step(
    loss_fn: Callable,           # (params, batch) -> (loss, aux_dict)
    tx,                          # GradientTransformation (raw, unwrapped)
    mesh: Mesh,
    *,
    microbatches: int = 1,
    zero3: bool = False,
    param_init_fn: Optional[Callable] = None,
    policy=None,                 # repro.precision.Policy or name, optional
    loss_scale=None,             # override the policy's default scaler
):
    """Returns (step_fn, init_fn, specs_for). loss_fn must be pure/jit-able.

    step_fn:   (params, opt_state, batch) -> (params, opt_state, metrics)
    init_fn:   rng -> (params, opt_state)
    specs_for: (params_like, opt_like) -> (params_pspec, opt_pspec)
    """
    if policy is not None:
        from repro import precision
        policy = precision.get_policy(policy)
        if policy.wants_wrapper:
            tx = precision.mixed_precision(tx, policy, loss_scale)
    mixed = policy is not None and policy.wants_wrapper

    def step_fn(params, opt_state, batch):
        if mixed:
            from repro.precision import loss_scale_value
            scale = loss_scale_value(opt_state)
        else:
            scale = None

        def grads_of(mb):
            def objective(p, b):
                loss, aux = loss_fn(p, b)
                if scale is None:
                    return loss, (loss, aux)
                # scale AFTER the fp32 loss reduction; grads flow scaled and
                # the mixed_precision wrapper divides the scale back out.
                return loss * scale.astype(loss.dtype), (loss, aux)

            (_, (loss, aux)), grads = jax.value_and_grad(
                objective, has_aux=True)(params, mb)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return loss, aux, grads

        if microbatches == 1:
            loss, aux, grads = grads_of(batch)
        else:
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: x.reshape((microbatches, -1) + x.shape[1:])[i]
                    if x.ndim >= 1 else x, batch)

            def body(carry, i):
                acc, loss_acc = carry
                loss, aux, grads = grads_of(slice_mb(i))
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), aux

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), auxs = jax.lax.scan(
                body, (zero, 0.0), jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = loss_sum / microbatches
            # auxs leaves are stacked (microbatches, ...): average numeric
            # aux over the whole global batch (reporting only the last
            # microbatch biased metrics like router_entropy); non-float aux
            # (counters, ids) keeps the final microbatch's value.
            aux = jax.tree.map(
                lambda a: jnp.mean(a, axis=0)
                if jnp.issubdtype(a.dtype, jnp.inexact) else a[-1], auxs)

        updates, new_opt = tx.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)

        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        if mixed:
            from repro.precision import all_finite, overflow_count
            metrics["grad_norm"] = gnorm / scale   # report unscaled
            metrics["grads_finite"] = all_finite(grads)
            metrics["loss_scale"] = loss_scale_value(new_opt)
            metrics["overflow_count"] = overflow_count(new_opt)
        return new_params, new_opt, metrics

    def init_fn(rng):
        assert param_init_fn is not None
        params = param_init_fn(rng)
        if policy is not None:
            params = policy.cast_params(params)
        return params, tx.init(params)

    # sharding specs require a concrete/abstract params tree; caller supplies
    # them lazily via specs_for.
    def specs_for(params_like, opt_like):
        pspec = shd.params_pspec(params_like, mesh, zero3=zero3)
        ospec = shd.opt_state_pspec(opt_like, pspec)
        return pspec, ospec

    return step_fn, init_fn, specs_for


def jit_train_step(step_fn, mesh: Mesh, pspec, ospec, batch_like):
    bspec = shd.batch_pspec(batch_like, mesh)
    metr = P()  # metrics replicated

    def shardings(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    return jax.jit(
        step_fn,
        in_shardings=(shardings(pspec), shardings(ospec), shardings(bspec)),
        out_shardings=(shardings(pspec), shardings(ospec), None),
    )


def build_prefill_step(forward_with_cache: Callable, mesh: Mesh):
    """forward_with_cache(params, batch) -> (logits_last, cache)."""
    return forward_with_cache


def build_serve_step(decode_fn: Callable, mesh: Optional[Mesh] = None, *,
                     sampler=None, params_like=None, cache_like=None,
                     donate_cache=True):
    """Build the jitted serving decode step — the decode_32k / long_500k
    shapes lower exactly this function.

    decode_fn(params, batch, cache) -> (logits, new_cache), e.g.
    Arch.decode_step. Returns a jitted

        step(params, tokens (B, 1), positions (B, 1), cache)
            -> (next_tokens (B,), new_cache)

    or, with a non-greedy `sampler` (repro.serving.sampler.Sampler),

        step(params, tokens, positions, cache, keys (B, 2) uint32)
            -> (next_tokens (B,), new_cache)

    Sampling always reads fp32 logits regardless of the serving precision
    policy (bf16/fp16 models still pick tokens from fp32 logits) and the
    per-slot `positions` thread through to the pooled cache. A greedy
    sampler (temperature == 0) compiles the exact argmax step — bit-equal
    to sampler=None. Compiled exactly once per (B, cache shape): the
    continuous-batching engine reuses it for its whole lifetime, and the
    paged pool's block tables / cursors are VALUES inside `cache`, so
    block churn never recompiles (asserted in tests/test_paged_cache.py).

    With a multi-device mesh plus params_like/cache_like abstract trees, the
    step is pjit'ed with the production shardings (params per the param
    rules, cache batch over data / head_dim over model — or, for paged
    arenas, blocks over data); on a single device it is a plain jit.
    donate_cache hands the old cache's buffers to the new one — the KV
    pool is updated in place instead of being double-buffered.
    """
    sampled = sampler is not None and not sampler.greedy
    stable = (sampler is not None and sampler.greedy
              and sampler.stable_tiebreak)

    if sampled:
        def step(params, tokens, positions, cache, keys):
            logits, new_cache = decode_fn(
                params, {"tokens": tokens, "positions": positions}, cache)
            nxt = sampler.sample(logits[:, -1, :].astype(jnp.float32), keys)
            return nxt, new_cache
    elif stable:
        # greedy with the bf16-ulp tie band (sampler.stable_argmax):
        # cross-layout-invariant token picks for bf16 differentials
        def step(params, tokens, positions, cache):
            logits, new_cache = decode_fn(
                params, {"tokens": tokens, "positions": positions}, cache)
            nxt = sampler.sample(logits[:, -1, :].astype(jnp.float32), None)
            return nxt, new_cache
    else:
        def step(params, tokens, positions, cache):
            logits, new_cache = decode_fn(
                params, {"tokens": tokens, "positions": positions}, cache)
            return greedy_next(logits.astype(jnp.float32)), new_cache

    donate = (3,) if donate_cache else ()
    if mesh is None or mesh.devices.size <= 1 or params_like is None:
        return jax.jit(step, donate_argnums=donate)

    pspec, tok_sh, cspec = serve_shardings(mesh, params_like, cache_like)
    in_sh = (pspec, tok_sh, tok_sh, cspec) + ((tok_sh,) if sampled else ())
    return jax.jit(
        step,
        in_shardings=in_sh,
        out_shardings=(tok_sh, cspec),
        donate_argnums=donate,
    )


def serve_shardings(mesh: Mesh, params_like, cache_like):
    """(params, tokens, cache) NamedSharding trees for the live sharded
    serve/verify steps: params per the param rules, cache per cache_pspec
    (paged arenas blocks-over-data, head_dim over model, integer
    bookkeeping replicated). The token sharding names only the leading
    batch dim, so one spec covers (B, 1) decode tokens, (B, K) verify
    tokens, (B,) outputs and (B, ..., 2) sampler keys alike."""
    def shardings(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    pspec = shardings(shd.params_pspec(params_like, mesh))
    cspec = shardings(shd.cache_pspec(cache_like, mesh))
    # batch sharding must respect divisibility (long_500k serves B=1):
    # the pooled cache's per-slot cursor carries the batch size
    baxes = shd.batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    idx = cache_like["index"] if isinstance(cache_like, dict) else None
    B = idx.shape[0] if getattr(idx, "ndim", 0) == 1 else None
    tok_sh = NamedSharding(
        mesh, P(baxes) if B is not None and B % bsize == 0 else P())
    return pspec, tok_sh, cspec


def build_verify_step(decode_fn: Callable, mesh: Optional[Mesh] = None, *,
                      sampler=None, params_like=None, cache_like=None,
                      donate_cache=True):
    """Build the jitted speculative-verify step: K tokens per slot in one
    forward against the pooled/paged cache.

    decode_fn(params, batch, cache) -> (logits (B, K, V), new_cache).
    Returns a jitted

        step(params, tokens (B, K), positions (B, K), cache)
            -> (tokens (B, K) int32, new_cache)

    or, with a non-greedy sampler,

        step(params, tokens, positions, cache, keys (B, K, 2) uint32)
            -> (tokens (B, K) int32, new_cache)

    Output row (b, i) is the target model's pick for the position AFTER
    positions[b, i] — i.e. it verifies draft token i+1 and, when every
    draft is accepted, row K-1 is the bonus next token. Each row is
    sampled exactly as build_serve_step samples its single row (same
    fp32 cast, same per-row key), which is what makes a speculative
    stream bit-identical to the non-spec stream: token t of slot b is
    picked from the same logits row with the same
    fold(request_key, t) key regardless of which verify round emitted
    it. Compiled once per (B, K, cache shape); block tables / cursors
    are cache VALUES, so accept/reject churn never recompiles.

    With a multi-device mesh plus params_like/cache_like abstract trees
    the step is pjit'ed with the same shardings as build_serve_step
    (serve_shardings); otherwise it is a plain jit.
    """
    sampled = sampler is not None and not sampler.greedy
    stable = (sampler is not None and sampler.greedy
              and sampler.stable_tiebreak)

    if sampled:
        def step(params, tokens, positions, cache, keys):
            logits, new_cache = decode_fn(
                params, {"tokens": tokens, "positions": positions}, cache)
            B, K, V = logits.shape
            flat = sampler.sample(logits.reshape(B * K, V).astype(
                jnp.float32), keys.reshape(B * K, 2))
            return flat.reshape(B, K), new_cache
    elif stable:
        def step(params, tokens, positions, cache):
            logits, new_cache = decode_fn(
                params, {"tokens": tokens, "positions": positions}, cache)
            B, K, V = logits.shape
            flat = sampler.sample(
                logits.reshape(B * K, V).astype(jnp.float32), None)
            return flat.reshape(B, K), new_cache
    else:
        def step(params, tokens, positions, cache):
            logits, new_cache = decode_fn(
                params, {"tokens": tokens, "positions": positions}, cache)
            nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1)
            return nxt.astype(jnp.int32), new_cache

    donate = (3,) if donate_cache else ()
    if mesh is None or mesh.devices.size <= 1 or params_like is None:
        return jax.jit(step, donate_argnums=donate)

    # same mesh path as build_serve_step: the (B, K) verify tokens and
    # keys shard on their leading batch dim exactly like (B, 1) decode
    # tokens, so the single-row and K-row steps share one sharding story
    pspec, tok_sh, cspec = serve_shardings(mesh, params_like, cache_like)
    in_sh = (pspec, tok_sh, tok_sh, cspec) + ((tok_sh,) if sampled else ())
    return jax.jit(
        step,
        in_shardings=in_sh,
        out_shardings=(tok_sh, cspec),
        donate_argnums=donate,
    )


def greedy_next(logits):
    """(B, 1, V) -> (B,) int32 greedy sample."""
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
